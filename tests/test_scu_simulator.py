"""Unit + property tests for the Tier-1 cycle-accurate SCU simulator."""

import random

import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.scu import (
    SCU,
    BarrierState,
    Cluster,
    Compute,
    Mem,
    Scu,
    run_barrier_bench,
    run_mutex_bench,
)
from repro.core.scu.engine import CoreState
from repro.core.scu.primitives import (
    DEFAULT_COSTS,
    scu_barrier,
    sw_barrier,
    tas_barrier,
)

POLICIES = ("scu", "tas", "sw", "tree", "tree4", "tree_ew", "fifo")
MODES = ("lockstep", "fastforward")


def make_cluster(n, mode="fastforward"):
    return Cluster(n_cores=n, scu=SCU(n_cores=n), mode=mode)


# ---------------------------------------------------------------------------
# Engine basics
# ---------------------------------------------------------------------------


def test_compute_only_program_cycles():
    cl = make_cluster(2)

    def prog(cluster, cid):
        yield Compute(10)
        yield Compute(5)

    cl.load([prog, prog])
    stats = cl.run()
    # one trailing cycle to observe generator completion
    assert stats.cycles == 16
    assert all(c.finished_at == 15 for c in stats.cores)
    assert all(c.active_cycles == 15 for c in stats.cores)
    assert all(c.gated_cycles == 0 for c in stats.cores)


def test_tcdm_load_store_roundtrip():
    cl = make_cluster(2)
    seen = {}

    def writer(cluster, cid):
        yield Mem("sw", 0x40, 1234)

    def reader(cluster, cid):
        yield Compute(4)  # let the writer go first
        v = yield Mem("lw", 0x40)
        seen["v"] = v

    cl.load([writer, reader])
    cl.run()
    assert seen["v"] == 1234


def test_tas_returns_value_then_locks():
    cl = make_cluster(2)
    got = {}

    def prog(cluster, cid):
        v = yield Mem("tas", 0x80)
        got[cid] = v

    cl.load([prog, prog])
    cl.run()
    # exactly one core saw the free value 0; the other saw -1
    assert sorted(got.values()) == [-1, 0]


def test_bank_conflict_serializes():
    cl = make_cluster(2)
    # two stores to the same bank in the same cycle -> one stalls
    def prog(cluster, cid):
        yield Mem("sw", 0x40, cid)

    cl.load([prog, prog])
    stats = cl.run()
    assert stats.bank_conflicts >= 1
    assert stats.cycles >= 2


# ---------------------------------------------------------------------------
# SCU barrier semantics (safety)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n", [2, 4, 8])
def test_scu_barrier_no_early_release(n):
    """No core may pass the barrier before the last one arrives."""
    cl = make_cluster(n)
    order = []

    def prog(delay):
        def p(cluster, cid):
            yield Compute(delay)
            yield from scu_barrier(cluster, cid)
            order.append((cid, cluster.cycle))

        return p

    delays = [1 + 7 * i for i in range(n)]
    cl.load([prog(d) for d in delays])
    cl.run()
    last_arrival = max(delays)
    for cid, cyc in order:
        assert cyc >= last_arrival, f"core {cid} passed at {cyc} < {last_arrival}"
    # all cores released within a few cycles of each other
    times = [c for _, c in order]
    assert max(times) - min(times) <= 2


def test_scu_barrier_reusable_back_to_back():
    n = 4
    cl = make_cluster(n)
    counts = [0] * n

    def prog(cluster, cid):
        for _ in range(10):
            yield from scu_barrier(cluster, cid)
            counts[cid] += 1

    cl.load([prog] * n)
    cl.run()
    assert counts == [10] * n


# ---------------------------------------------------------------------------
# Mutex semantics (mutual exclusion + liveness), all three variants
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("variant", ["SCU", "TAS", "SW"])
@pytest.mark.parametrize("n", [2, 4, 8])
def test_mutex_mutual_exclusion_and_liveness(variant, n):
    cl = make_cluster(n)
    inside = {"count": 0, "max": 0, "entries": 0}

    def section(cluster, cid):
        # emulate the critical section body with explicit begin/end marks
        inside["count"] += 1
        inside["max"] = max(inside["max"], inside["count"])
        inside["entries"] += 1
        yield Compute(3)
        inside["count"] -= 1

    def prog(cluster, cid):
        for _ in range(5):
            if variant == "SCU":
                yield Compute(1)
                yield Scu("elw", ("mutex", 0, "lock"))
                yield from section(cluster, cid)
                yield Scu("write", ("mutex", 0, "unlock"), 0)
            elif variant == "SW":
                while True:
                    v = yield Mem("tas", 0x10C)
                    if v == 0:
                        break
                    yield Compute(1)
                yield from section(cluster, cid)
                yield Mem("sw", 0x10C, 0)
            else:  # TAS
                v = yield Mem("tas", 0x10C)
                while v != 0:
                    yield Scu("elw", ("notifier", 1, "wait"))
                    v = yield Mem("tas", 0x10C)
                yield from section(cluster, cid)
                yield Mem("sw", 0x10C, 0)
                yield Scu("write", ("notifier", 1, "trigger"), 0)

    cl.load([prog] * n)
    cl.run(max_cycles=2_000_000)
    assert inside["max"] == 1, "mutual exclusion violated"
    assert inside["entries"] == 5 * n, "liveness violated (missing entries)"


def test_scu_mutex_message_passing():
    """The unlocking core's 32-bit message reaches the next lock owner."""
    n = 2
    cl = make_cluster(n)
    received = {}

    def first(cluster, cid):
        yield Scu("elw", ("mutex", 0, "lock"))
        yield Compute(5)
        yield Scu("write", ("mutex", 0, "unlock"), 0xBEEF)

    def second(cluster, cid):
        yield Compute(3)  # arrive strictly later
        msg = yield Scu("elw", ("mutex", 0, "lock"))
        received["msg"] = msg
        yield Scu("write", ("mutex", 0, "unlock"), 0)

    cl.load([first, second])
    cl.run()
    assert received["msg"] == 0xBEEF


# ---------------------------------------------------------------------------
# Software barrier correctness under random arrival skew (property)
# ---------------------------------------------------------------------------


@settings(max_examples=20, deadline=None)
@given(
    delays=st.lists(st.integers(min_value=1, max_value=200), min_size=4, max_size=4),
    variant=st.sampled_from(["SCU", "TAS", "SW"]),
)
def test_barrier_safety_random_skew(delays, variant):
    n = len(delays)
    cl = make_cluster(n)
    bstate = BarrierState(n)
    passed = []

    def prog(delay):
        def p(cluster, cid):
            yield Compute(delay)
            if variant == "SCU":
                yield from scu_barrier(cluster, cid)
            elif variant == "TAS":
                yield from tas_barrier(cluster, cid, bstate)
            else:
                yield from sw_barrier(cluster, cid, bstate)
            passed.append((cid, cluster.cycle))

        return p

    cl.load([prog(d) for d in delays])
    cl.run(max_cycles=1_000_000)
    assert len(passed) == n
    last_arrival = max(delays)
    for cid, cyc in passed:
        assert cyc >= last_arrival


@settings(max_examples=10, deadline=None)
@given(
    t_crit=st.integers(min_value=0, max_value=20),
    variant=st.sampled_from(["SCU", "TAS", "SW"]),
)
def test_mutex_benchmark_terminates_and_is_positive(t_crit, variant):
    r = run_mutex_bench(variant, 4, t_crit=t_crit, iters=8)
    assert r.cycles_total > 0
    assert r.prim_cycles >= 0


# ---------------------------------------------------------------------------
# Event buffer semantics
# ---------------------------------------------------------------------------


def test_notifier_event_latched_until_consumed():
    """A notifier fired before the elw must still wake the core (level
    semantics via the event buffer, not edge semantics)."""
    n = 2
    cl = make_cluster(n)
    woke = {}

    def sender(cluster, cid):
        yield Scu("write", ("notifier", 3, "trigger"), 0b10)  # target core 1

    def receiver(cluster, cid):
        yield Compute(20)  # the event arrives long before we wait
        v = yield Scu("elw", ("notifier", 3, "wait"))
        woke["buffer"] = v

    cl.load([sender, receiver])
    stats = cl.run(max_cycles=10_000)
    assert "buffer" in woke
    # the receiver should never have been clock-gated: event was pending
    assert stats.cores[1].gated_cycles == 0


def test_notifier_broadcast_on_zero_mask():
    n = 4
    cl = make_cluster(n)
    woke = []

    def sender(cluster, cid):
        yield Compute(5)
        yield Scu("write", ("notifier", 2, "trigger"), 0)  # broadcast

    def receiver(cluster, cid):
        yield Scu("elw", ("notifier", 2, "wait"))
        woke.append(cid)

    cl.load([sender] + [receiver] * (n - 1))
    cl.run(max_cycles=10_000)
    assert sorted(woke) == [1, 2, 3]


# ---------------------------------------------------------------------------
# Event-FIFO extension: producer-consumer push/pop over the SCU (Sec. 4.3)
# ---------------------------------------------------------------------------


def test_fifo_consumer_sleeps_clock_gated_until_push():
    """A pop on an empty FIFO clock-gates the consumer until the producer's
    push is matched to it -- the FIFO analogue of the elw barrier wait."""
    cl = make_cluster(2)
    got = {}

    def producer(cluster, cid):
        yield Compute(30)
        yield Scu("write", ("fifo", 1, "push"), 42)

    def consumer(cluster, cid):
        v = yield Scu("elw", ("fifo", 1, "pop"))
        got["v"] = v

    cl.load([producer, consumer])
    st = cl.run(max_cycles=10_000)
    assert got["v"] == 42
    assert st.cores[1].gated_cycles >= 25  # slept through the producer's SFR


def test_fifo_events_delivered_in_order():
    """Queued events reach the consumer in push order, one per pop."""
    cl = make_cluster(2)
    got = []

    def producer(cluster, cid):
        for v in (7, 11, 13):
            yield Scu("write", ("fifo", 1, "push"), v)
            yield Compute(5)

    def consumer(cluster, cid):
        for _ in range(3):
            v = yield Scu("elw", ("fifo", 1, "pop"))
            got.append(v)

    cl.load([producer, consumer])
    cl.run(max_cycles=10_000)
    assert got == [7, 11, 13]


def test_fifo_event_latched_when_pushed_before_pop():
    """An event pushed long before the pop must still be matched (queue
    semantics, not edge semantics); the consumer never needs to sleep."""
    cl = make_cluster(2)
    got = {}

    def producer(cluster, cid):
        yield Scu("write", ("fifo", 1, "push"), 99)

    def consumer(cluster, cid):
        yield Compute(20)
        v = yield Scu("elw", ("fifo", 1, "pop"))
        got["v"] = v

    cl.load([producer, consumer])
    st = cl.run(max_cycles=10_000)
    assert got["v"] == 99
    assert st.cores[1].gated_cycles == 0


def test_fifo_multi_consumer_each_matched_one_event():
    """Two consumers on one queue: the comparator matches one queued event
    per pending popper; nobody pops twice, nobody starves."""
    n = 3
    cl = make_cluster(n)
    got = {}

    def producer(cluster, cid):
        yield Compute(10)
        yield Scu("write", ("fifo", 1, "push"), 1)
        yield Compute(10)
        yield Scu("write", ("fifo", 1, "push"), 2)

    def consumer(cluster, cid):
        v = yield Scu("elw", ("fifo", 1, "pop"))
        got[cid] = v

    cl.load([producer, consumer, consumer])
    cl.run(max_cycles=10_000)
    assert sorted(got) == [1, 2]
    assert sorted(got.values()) == [1, 2]


def test_fifo_overflow_drops_and_counts():
    scu = SCU(n_cores=2, fifo_depth=2)
    cl = Cluster(n_cores=2, scu=scu)

    def producer(cluster, cid):
        for v in range(4):  # two more than the queue holds
            yield Scu("write", ("fifo", 1, "push"), v)

    def idle(cluster, cid):
        yield Compute(1)

    cl.load([producer, idle])
    cl.run(max_cycles=10_000)
    assert scu.fifos[1].dropped == 2
    assert list(scu.fifos[1].fifo) == [0, 1]


def test_fifo_level_read_nonblocking():
    cl = make_cluster(2)
    got = {}

    def producer(cluster, cid):
        yield Scu("write", ("fifo", 1, "push"), 5)
        yield Scu("write", ("fifo", 1, "push"), 6)
        lvl = yield Scu("read", ("fifo", 1, "level"))
        got["level"] = lvl

    def idle(cluster, cid):
        yield Compute(1)

    cl.load([producer, idle])
    cl.run(max_cycles=10_000)
    assert got["level"] == 2


def test_fifo_barrier_back_to_back_no_token_theft():
    """Private release queues: a fast core re-entering the next barrier must
    not be released by a leftover token of the previous one."""
    from repro.sync import get_policy

    policy = get_policy("fifo")
    n = 8
    cl = make_cluster(n)
    state = policy.make_sim_state(n)
    passes = [[] for _ in range(n)]

    def prog(cluster, cid):
        for k in range(6):
            # core n-1 is persistently slow: fast cores lap it into the next
            # barrier while its release tokens are still being delivered
            yield Compute(200 if cid == n - 1 else 1)
            yield from policy.sim_barrier(cluster, cid, state, None)
            passes[cid].append(cluster.cycle)

    cl.load([prog] * n)
    cl.run(max_cycles=1_000_000)
    for k in range(5):
        # nobody may pass barrier k+1 before everyone has passed barrier k
        assert min(p[k + 1] for p in passes) >= max(p[k] for p in passes)


# ---------------------------------------------------------------------------
# Paper validation: Table 1 (cycles)
# ---------------------------------------------------------------------------

PAPER_BARRIER = {"SCU": (6, 6, 6), "TAS": (52, 91, 176), "SW": (47, 87, 176)}


@pytest.mark.parametrize("variant", ["SCU", "TAS", "SW"])
def test_table1_barrier_cycles(variant):
    for n, paper in zip((2, 4, 8), PAPER_BARRIER[variant]):
        r = run_barrier_bench(variant, n, sfr=0, iters=32)
        tol = 0.01 if variant == "SCU" else 0.12
        assert abs(r.prim_cycles - paper) <= max(1.0, tol * paper), (
            f"{variant} barrier @{n} cores: {r.prim_cycles} vs paper {paper}"
        )


def test_scu_barrier_cost_independent_of_core_count():
    costs = [run_barrier_bench("SCU", n, 0, iters=32).prim_cycles for n in (2, 4, 8)]
    assert max(costs) - min(costs) < 0.5


def test_sw_barrier_cost_grows_with_core_count():
    costs = [run_barrier_bench("SW", n, 0, iters=32).prim_cycles for n in (2, 4, 8)]
    assert costs[0] < costs[1] < costs[2]


def test_scu_barrier_six_active_cycles_per_core():
    r = run_barrier_bench("SCU", 8, sfr=0, iters=32)
    per_core = r.active_core_cycles_per_iter / 8
    assert abs(per_core - 6.0) <= 0.5  # Fig. 4: six active core cycles


# ---------------------------------------------------------------------------
# Engine modes: golden cycle counts + lockstep-vs-fastforward bit-exactness
# ---------------------------------------------------------------------------

# cycles_per_iter measured on the seed (pre-fast-forward) lockstep engine at
# iters=16 -- the engine rewrite must not move ANY of these by even a cycle.
# (tree4/fifo rows were recorded when those policies landed, same protocol:
# lockstep reference first, fastforward asserted identical.)
GOLDEN_BARRIER = {  # policy: (2, 4, 8 cores), sfr=0
    "scu": (6.0625, 6.0625, 6.0625),
    "tas": (51.5000, 89.6250, 169.9375),
    "sw": (49.1875, 88.1250, 172.5000),
    "tree": (20.4375, 29.3750, 44.1250),
    "tree4": (20.4375, 25.5000, 42.4375),
    "tree_ew": (19.2500, 27.2500, 35.2500),
    "fifo": (17.0625, 29.3125, 61.3125),
}
GOLDEN_MUTEX_T10 = {  # policy: (2, 4, 8 cores), t_crit=10
    "scu": (30.1875, 60.1875, 120.1875),
    "tas": (32.4375, 65.1875, 131.1875),
    "sw": (30.1250, 63.8125, 129.1875),
    "tree": (30.1250, 63.8125, 129.1875),
    "tree4": (30.1250, 63.8125, 129.1875),
    "tree_ew": (30.1250, 63.8125, 129.1875),
    "fifo": (32.1875, 64.1875, 128.1875),
}


@pytest.mark.parametrize("mode", MODES)
@pytest.mark.parametrize("policy", POLICIES)
def test_golden_cycles_locked(policy, mode):
    """Both engine modes reproduce the seed engine's exact cycle counts."""
    for i, n in enumerate((2, 4, 8)):
        rb = run_barrier_bench(policy, n, sfr=0, iters=16, mode=mode)
        assert rb.cycles_per_iter == pytest.approx(
            GOLDEN_BARRIER[policy][i], abs=1e-9
        ), f"{policy} barrier @{n} cores ({mode})"
        rm = run_mutex_bench(policy, n, t_crit=10, iters=16, mode=mode)
        assert rm.cycles_per_iter == pytest.approx(
            GOLDEN_MUTEX_T10[policy][i], abs=1e-9
        ), f"{policy} mutex @{n} cores ({mode})"


@pytest.mark.parametrize("policy", POLICIES)
def test_engine_modes_bit_exact_on_microbenches(policy):
    """Full ClusterStats equality (cycles, per-core active/comp/wait/gated/
    stall, tcdm/tas/scu counts) between the two modes on the Table-1
    program shapes, including nonzero SFR and critical sections."""
    for n in (2, 4, 8):
        a = run_barrier_bench(policy, n, sfr=37, iters=8, mode="lockstep")
        b = run_barrier_bench(policy, n, sfr=37, iters=8, mode="fastforward")
        assert a.stats == b.stats, f"{policy} barrier @{n}: stats diverged"
        a = run_mutex_bench(
            policy, n, t_crit=10, sfr=11, iters=8, mode="lockstep"
        )
        b = run_mutex_bench(
            policy, n, t_crit=10, sfr=11, iters=8, mode="fastforward"
        )
        assert a.stats == b.stats, f"{policy} mutex @{n}: stats diverged"


@pytest.mark.parametrize("app_name", ["fft", "dwt", "livermore2"])
def test_engine_modes_bit_exact_on_apps(app_name):
    """Table-2 app skeletons: every AppResult field derived from the stats
    (cycles, energy, power, sync shares) agrees between the modes."""
    from repro.core.scu.apps import APPS, run_app

    for policy in ("scu", "sw"):
        a = run_app(APPS[app_name], policy, mode="lockstep")
        b = run_app(APPS[app_name], policy, mode="fastforward")
        assert a == b, f"{app_name}/{policy}: app results diverged"


def _run_random_mix(
    seed: int, policy_name: str, n: int, mode: str, with_mutex: bool = True
):
    """Random program mix: per-core compute skew, shared-policy barriers,
    critical sections, and raw TCDM traffic -- all parameters drawn up
    front so both engine modes replay the identical program.

    ``with_mutex=False`` drops the critical sections: at 256 cores the
    software mutexes serialize ~O(n^2) spin cycles per round, which makes
    the *lockstep reference* side of the cross-check the bottleneck; the
    mutex path is covered at 64/128 cores instead."""
    from repro.sync import get_policy

    rng = random.Random(seed)
    rounds = 3
    delays = [[rng.randint(1, 80) for _ in range(rounds)] for _ in range(n)]
    tcrits = [rng.randint(0, 12) for _ in range(rounds)]
    # random traffic lives far above every sync-variable range: the tree
    # policies' per-core flag words reach 0x200 + 4*cid (0x5FC at 256
    # cores), and a random store clobbering an arrival flag livelocks the
    # barrier by design
    mem_ops = [
        [
            (rng.choice(("lw", "sw")), 0x8000 + 4 * rng.randint(0, 15))
            for _ in range(rng.randint(0, 4))
        ]
        for _ in range(n)
    ]
    policy = get_policy(policy_name)
    cl = make_cluster(n, mode=mode)
    state = policy.make_sim_state(n)

    def make_prog(cid):
        def prog(cluster, _cid):
            for r in range(rounds):
                yield Compute(delays[cid][r])
                for kind, addr in mem_ops[cid]:
                    yield Mem(kind, addr, cid)
                yield from policy.sim_barrier(cluster, _cid, state, DEFAULT_COSTS)
                if with_mutex:
                    yield from policy.sim_mutex(
                        cluster, _cid, tcrits[r], state, DEFAULT_COSTS
                    )
        return prog

    cl.load([make_prog(cid) for cid in range(n)])
    return cl.run(max_cycles=2_000_000)


@settings(max_examples=8, deadline=None)
@given(
    seed=st.integers(min_value=0, max_value=9999),
    policy=st.sampled_from(list(POLICIES)),
    n=st.sampled_from([2, 4, 8]),
)
def test_fastforward_matches_lockstep_on_random_programs(seed, policy, n):
    """Cross-check: randomized programs produce bit-identical ClusterStats
    under the event-driven engine and the lockstep reference."""
    lock = _run_random_mix(seed, policy, n, "lockstep")
    fast = _run_random_mix(seed, policy, n, "fastforward")
    assert lock == fast, (
        f"engines diverged (policy={policy}, n={n}, seed={seed}): "
        f"{lock.cycles} vs {fast.cycles} cycles"
    )


def _run_random_chain(seed: int, policy_name: str, n: int, mode: str):
    """Random pipelined chain: per-(item, stage) work and a random credit
    depth, drawn up front so both engine modes replay the same program.
    Exercises the FIFO fast path (clock-gated pops between spans) for the
    ``fifo`` policy and the barrier-synchronous emulation for the rest."""
    from repro.core.scu.programs import barrier_pipeline_programs
    from repro.sync import get_policy

    rng = random.Random(seed)
    items = rng.randint(2, 9)
    work = [[rng.randint(1, 120) for _ in range(n)] for _ in range(items)]
    depth = rng.choice((1, 2, 4, 8))
    policy = get_policy(policy_name)
    cl = make_cluster(n, mode=mode)
    state = policy.make_sim_state(n)
    maker = getattr(policy, "make_pipeline_programs", None)
    if maker is not None:
        programs = maker(n, work, state, DEFAULT_COSTS, depth)
    else:
        programs = barrier_pipeline_programs(policy, n, work, state, DEFAULT_COSTS)
    cl.load(programs)
    return cl.run(max_cycles=2_000_000)


@settings(max_examples=8, deadline=None)
@given(
    seed=st.integers(min_value=0, max_value=9999),
    policy=st.sampled_from(["fifo", "scu", "sw"]),
    n=st.sampled_from([2, 4, 8]),
)
def test_fastforward_matches_lockstep_on_random_chains(seed, policy, n):
    """FIFO workloads: randomized pipelined chains produce bit-identical
    ClusterStats under the event-driven engine and the lockstep reference."""
    lock = _run_random_chain(seed, policy, n, "lockstep")
    fast = _run_random_chain(seed, policy, n, "fastforward")
    assert lock == fast, (
        f"engines diverged on chain (policy={policy}, n={n}, seed={seed}): "
        f"{lock.cycles} vs {fast.cycles} cycles"
    )


def test_chain_bench_modes_bit_exact():
    """run_chain_bench: full ClusterStats equality between the two engine
    modes, including the Table-2 pipelined app variant."""
    from repro.core.scu.apps import APPS, run_app_pipelined
    from repro.core.scu.programs import run_chain_bench

    for policy in ("fifo", "scu"):
        a = run_chain_bench(policy, 8, sfr=37, iters=8, depth=4, mode="lockstep")
        b = run_chain_bench(policy, 8, sfr=37, iters=8, depth=4, mode="fastforward")
        assert a.stats == b.stats, f"{policy} chain: stats diverged"
    a = run_app_pipelined(APPS["livermore2"], "fifo", mode="lockstep")
    b = run_app_pipelined(APPS["livermore2"], "fifo", mode="fastforward")
    assert a == b, "pipelined app results diverged"


def test_fifo_chain_fastforward_skips_quiescent_spans():
    """The FIFO fast path must stay event-driven: an SFR-dominated chain is
    covered almost entirely by span jumps (clock-gated pops between spans
    must not degrade the engine to lockstep)."""
    from repro.sync import get_policy

    policy = get_policy("fifo")
    n = 4
    cl = make_cluster(n, mode="fastforward")
    state = policy.make_sim_state(n)
    work = [[400] * n for _ in range(6)]
    cl.load(policy.make_pipeline_programs(n, work, state, DEFAULT_COSTS, 4))
    st_ = cl.run()
    assert cl.ff_spans > 0
    assert cl.ff_cycles > 0.8 * st_.cycles


def test_fastforward_actually_skips():
    """Guard against the fast path silently degrading to lockstep: an
    SFR-dominated program must be covered almost entirely by span jumps."""
    cl = make_cluster(4, mode="fastforward")

    def prog(cluster, cid):
        for _ in range(4):
            yield Compute(500)
            yield from scu_barrier(cluster, cid)

    cl.load([prog] * 4)
    st_ = cl.run()
    assert cl.ff_spans > 0
    assert cl.ff_cycles > 0.9 * st_.cycles


# ---------------------------------------------------------------------------
# Event-FIFO blocking push (push_wait): backpressure without a credit queue
# ---------------------------------------------------------------------------


def test_push_wait_completes_immediately_when_room():
    """With room in the queue, the blocking push is accepted on the next
    comparator evaluation and echoes the pushed value back."""
    cl = make_cluster(2)
    got = {}

    def producer(cluster, cid):
        v = yield Scu("elw", ("fifo", 1, "push_wait"), 42)
        got["echo"] = v

    def consumer(cluster, cid):
        yield Compute(10)
        got["v"] = yield Scu("elw", ("fifo", 1, "pop"))

    cl.load([producer, consumer])
    st = cl.run(max_cycles=10_000)
    assert got["v"] == 42
    assert got["echo"] == 42
    assert st.cores[0].gated_cycles == 0  # never had to sleep


def test_push_wait_blocks_until_consumer_drains():
    """A blocking push against a full queue clock-gates the producer until a
    pop frees a slot; no event is ever dropped."""
    scu = SCU(n_cores=2, fifo_depth=2)
    cl = Cluster(n_cores=2, scu=scu)
    got = []

    def producer(cluster, cid):
        for v in (1, 2, 3, 4):
            yield Scu("elw", ("fifo", 1, "push_wait"), v)

    def consumer(cluster, cid):
        yield Compute(60)  # let the producer fill the queue and block
        for _ in range(4):
            v = yield Scu("elw", ("fifo", 1, "pop"))
            got.append(v)
            yield Compute(20)

    cl.load([producer, consumer])
    st = cl.run(max_cycles=100_000)
    assert got == [1, 2, 3, 4]
    assert scu.fifos[1].dropped == 0
    assert st.cores[0].gated_cycles > 20  # blocked on the full queue


def test_push_wait_full_queue_with_popper_makes_progress_every_cycle():
    """Pop and blocked push can complete in the same evaluation: a full
    queue with a waiting consumer still moves one item per cycle."""
    scu = SCU(n_cores=2, fifo_depth=1)
    cl = Cluster(n_cores=2, scu=scu)
    got = []

    def producer(cluster, cid):
        for v in (5, 6, 7):
            yield Scu("elw", ("fifo", 1, "push_wait"), v)

    def consumer(cluster, cid):
        for _ in range(3):
            got.append((yield Scu("elw", ("fifo", 1, "pop"))))

    cl.load([producer, consumer])
    cl.run(max_cycles=10_000)
    assert got == [5, 6, 7]
    assert scu.fifos[1].dropped == 0


def test_push_wait_next_event_bound_contract():
    """The extension contract: ``next_event_bound() == 0`` exactly when
    ``evaluate`` could move an event this cycle, for every pusher/popper/
    occupancy combination of the blocking push."""
    from repro.core.scu.extensions import EventFifo

    for occupancy in (0, 1, 2):
        for n_push in (0, 1):
            for n_pop in (0, 1):
                f = EventFifo(index=0, depth=2)
                for v in range(occupancy):
                    f.push(v)
                if n_push:
                    f.register_pusher(0, 9)
                if n_pop:
                    f.register_popper(1)
                bound = f.next_event_bound()
                scu = SCU(n_cores=2)
                fired = f.evaluate(scu.base)
                if bound == 0:
                    assert fired > 0, (
                        f"bound 0 but no event (occ={occupancy}, "
                        f"push={n_push}, pop={n_pop})"
                    )
                else:
                    assert bound is None
                    assert fired == 0, (
                        f"bound None but evaluate fired (occ={occupancy}, "
                        f"push={n_push}, pop={n_pop})"
                    )


def test_work_queue_all_policies_deliver_all_items():
    """The multi-producer work queue terminates with every item consumed
    under every registered policy (fifo runs push_wait natively)."""
    from repro.core.scu.programs import run_work_queue_bench

    for policy in POLICIES:
        r = run_work_queue_bench(policy, 2, 2, items=12, t_produce=5,
                                 t_consume=5)
        assert r.cycles_total > 0, policy


# ---------------------------------------------------------------------------
# Tree idle-wait release variant (SCU notifier instead of the release spin)
# ---------------------------------------------------------------------------


def test_tree_ew_losers_sleep_instead_of_spinning():
    """The idle-wait release clock-gates the losers: with a straggler
    champion-side arrival, waiting cores accumulate gated (not spin)
    cycles, unlike the release-word spin variant."""
    from repro.sync import get_policy

    def run_policy(name):
        policy = get_policy(name)
        n = 8
        cl = make_cluster(n)
        state = policy.make_sim_state(n)

        def prog(cluster, cid):
            yield Compute(400 if cid == 0 else 1)  # champion is the straggler
            yield from policy.sim_barrier(cluster, cid, state, None)

        cl.load([prog] * n)
        return cl.run(max_cycles=100_000)

    spin = run_policy("tree")
    ew = run_policy("tree_ew")
    assert ew.total_gated > spin.total_gated
    # the release-word spin burns active cycles on the stragglers' behalf
    assert ew.total_active < spin.total_active


def test_tree_ew_back_to_back_no_stale_wakeup():
    """A stale notifier bit must never release a loser early in
    back-to-back barriers (targeted trigger + per-core consumption)."""
    from repro.sync import get_policy

    policy = get_policy("tree_ew")
    n = 8
    cl = make_cluster(n)
    state = policy.make_sim_state(n)
    passes = [[] for _ in range(n)]

    def prog(cluster, cid):
        for k in range(6):
            yield Compute(200 if cid == (k % n) else 1)
            yield from policy.sim_barrier(cluster, cid, state, None)
            passes[cid].append(cluster.cycle)

    cl.load([prog] * n)
    cl.run(max_cycles=1_000_000)
    for k in range(5):
        assert min(p[k + 1] for p in passes) >= max(p[k] for p in passes)


# ---------------------------------------------------------------------------
# Vectorized structure-of-arrays engine: 16..256-core cross-checks
# ---------------------------------------------------------------------------


# (n_cores, policies): the expensive software disciplines are sampled more
# sparsely at the largest sizes -- reference-stepping a contended 256-core
# cluster is exactly the cost the vectorized engine exists to avoid.
# (n_cores, policies, with_mutex): the 256-core rows are barrier-focused --
# the software mutexes' O(n^2) serialized spin makes the lockstep
# *reference* side the bottleneck, and the mutex path is covered at 64/128.
LARGE_CROSS_CHECKS = (
    (16, ("scu", "tas", "sw", "tree", "tree4", "tree_ew", "fifo"), True),
    (64, ("sw", "tas", "tree4", "fifo"), True),
    (128, ("sw", "tree", "fifo"), True),
    (256, ("scu", "tree4", "tree_ew", "fifo"), False),
)


@pytest.mark.parametrize("n,policies,with_mutex", LARGE_CROSS_CHECKS)
def test_vectorized_matches_lockstep_on_large_clusters(n, policies, with_mutex):
    """Randomized lockstep-vs-vectorized cross-check at 16/64/128/256 cores:
    the structure-of-arrays step, the vectorized arbiter and the spin-phase
    batch resolver must be bit-exact against the scalar reference."""
    for i, policy in enumerate(policies):
        lock = _run_random_mix(1000 + 7 * n + i, policy, n, "lockstep", with_mutex)
        fast = _run_random_mix(1000 + 7 * n + i, policy, n, "fastforward", with_mutex)
        assert lock == fast, f"engines diverged (policy={policy}, n={n})"


@pytest.mark.parametrize("n", [16, 64])
def test_vectorized_work_queue_matches_lockstep(n):
    """The work-queue shapes (mutex churn + clock-gated FIFO pops) at
    vectorized cluster sizes."""
    from repro.core.scu.programs import run_work_queue_bench

    for policy in ("sw", "fifo"):
        a = run_work_queue_bench(policy, n // 2, n - n // 2, items=2 * n,
                                 mode="lockstep")
        b = run_work_queue_bench(policy, n // 2, n - n // 2, items=2 * n,
                                 mode="fastforward")
        assert a.stats == b.stats, f"{policy}@{n}: work queue diverged"


def _adversarial_spin_program(n):
    """A spin-phase-heavy program that drags the batch resolver on and off:

    * long pure-spin phases (everyone polls while core 0 computes) that the
      resolver must batch, including one long enough to trip the period
      detector;
    * mid-phase disqualifications: a waker that interleaves plain stores
      and SCU notifier traffic (armed comparators force full steps);
    * poll hits landing at staggered times, including a TAS lock handoff.
    """
    from repro.core.scu.engine import Poll

    A_FLAG = 0x900
    A_LOCK = 0x904

    def prog(cluster, cid):
        for rnd in range(3):
            if cid == 0:
                yield Compute(120 + 400 * rnd)  # spin horizon (long in rnd 2)
                yield Mem("sw", A_FLAG, rnd + 1)  # release the lw spinners
                yield Compute(5)
                yield Scu("write", ("notifier", 2, "trigger"), 0b10)
                yield Mem("sw", A_LOCK, 0)  # hand the TAS lock around
            elif cid == 1:
                # sleeps mid-phase: the resolver must treat it as spectator
                yield Scu("elw", ("notifier", 2, "wait"))
                yield Compute(3)
            elif cid % 2 == 0:
                yield Poll("lw", A_FLAG, until=rnd + 1, hit_cycles=2,
                           miss_cycles=4, hit_instr=1, miss_instr=2)
                yield Compute(7)
            else:
                yield Poll("tas", A_LOCK, until=0, hit_cycles=1,
                           miss_cycles=3, hit_instr=1, miss_instr=1)
                yield Compute(2)
                yield Mem("sw", A_LOCK, 0)
        # final all-spin phase with no spectator: ends only by the hits
        if cid == 0:
            yield Mem("sw", A_FLAG, 99)
        else:
            yield Poll("lw", A_FLAG, until=99, hit_cycles=2, miss_cycles=4,
                       hit_instr=1, miss_instr=2)

    return prog


@pytest.mark.parametrize("n", [8, 16, 64])
def test_spin_batch_resolver_adversarial_program(n):
    """The adversarial program forces the spin-phase resolver on and off
    mid-run; stats must stay bit-exact and the resolver must actually
    engage (and batch a long phase through the period detector)."""
    def build(mode):
        cl = make_cluster(n, mode=mode)
        cl.load([_adversarial_spin_program(n)] * n)
        return cl

    lock = build("lockstep")
    a = lock.run(max_cycles=2_000_000)
    fast = build("fastforward")
    b = fast.run(max_cycles=2_000_000)
    assert a == b, f"adversarial spin program diverged at {n} cores"
    assert fast.ff_batch_spans > 0, "spin-phase resolver never engaged"
    assert fast.ff_batch_cycles > 0


def test_spin_batch_resolver_period_jump_on_long_phase():
    """A single long spin phase (one straggler, everyone else polling) must
    be covered almost entirely by batch-resolved cycles, not full steps --
    the period detector collapsing the horizon is what makes the
    imbalanced-app shapes affordable."""
    n = 8
    cl = make_cluster(n, mode="fastforward")
    from repro.core.scu.engine import Poll

    def prog(cluster, cid):
        if cid == 0:
            yield Compute(20_000)
            yield Mem("sw", 0x900, 1)
        else:
            yield Poll("lw", 0x900, until=1, hit_cycles=2, miss_cycles=4,
                       hit_instr=1, miss_instr=2)

    cl.load([prog] * n)
    st = cl.run()
    assert cl.ff_batch_cycles > 0.95 * st.cycles


# ---------------------------------------------------------------------------
# Batched fleet simulation: bit-exact parity with sequential dispatch
# ---------------------------------------------------------------------------


def _random_fleet_benches(seed: int):
    """A mixed fleet: different policies, core counts (8/16/64), shapes
    (barrier/mutex/chain/work-queue), SFRs and iteration counts -- so
    members finish at very different times and every batched kernel sees
    heterogeneous segments.  Deterministic in ``seed`` so the sequential
    and fleet passes replay identical programs."""
    from repro.core.scu.programs import (
        prep_barrier_bench,
        prep_chain_bench,
        prep_mutex_bench,
        prep_work_queue_bench,
    )

    rng = random.Random(seed)
    benches = []
    for _ in range(rng.randint(5, 9)):
        policy = rng.choice(POLICIES)
        n = rng.choice((8, 8, 8, 16, 64))  # 8 thrice: the new fleet regime
        shape = rng.choice(("barrier", "mutex", "chain", "wq")) if n <= 16 \
            else "barrier"  # software mutex herds at 64 cores are O(n^2)
        iters = rng.randint(2, 10)  # early/late finish times in one batch
        if shape == "barrier":
            benches.append(prep_barrier_bench(
                policy, n, sfr=rng.choice((0, 13, 100, 900)), iters=iters
            ))
        elif shape == "mutex":
            benches.append(prep_mutex_bench(
                policy, n, t_crit=rng.randint(0, 12),
                sfr=rng.choice((0, 37)), iters=iters,
            ))
        elif shape == "chain":
            benches.append(prep_chain_bench(
                policy, n, sfr=rng.choice((20, 150)), iters=iters,
                depth=rng.choice((1, 4, 8)),
            ))
        else:
            benches.append(prep_work_queue_bench(
                policy, n // 2, n - n // 2, items=2 * n,
                t_produce=rng.randint(1, 40), t_consume=rng.randint(1, 40),
            ))
    return benches


@settings(max_examples=6, deadline=None)
@given(seed=st.integers(min_value=0, max_value=9999))
def test_fleet_matches_sequential_on_random_mixed_fleets(seed):
    """Randomized fleet-vs-sequential parity: a mixed batch must produce
    ClusterStats bit-identical to per-config Cluster.run() -- the fleet
    engine's core contract."""
    from repro.core.scu.programs import make_fleet

    seq = [b.run_sequential() for b in _random_fleet_benches(seed)]
    fleet = make_fleet(_random_fleet_benches(seed))
    for a, b in zip(seq, fleet):
        assert a.stats == b.stats, (
            f"fleet diverged (seed={seed}): {a.variant}/{a.primitive}"
            f"@{a.n_cores}"
        )


def _sleeper_config(span=5000):
    """All cores compute a long span, then meet at the hardware barrier --
    one long quiescent stretch the fleet must cover with per-config jumps."""
    from repro.core.scu.engine import FleetConfig

    cl = make_cluster(8)

    def prog(cluster, cid):
        yield Compute(span)
        yield from scu_barrier(cluster, cid)

    return FleetConfig(cluster=cl, programs=[prog] * 8)


def _churner_config(items=100):
    """A FIFO producer-consumer pair whose comparator fires continuously --
    armed-extension activity that must never leak into another config's
    quiescent bound."""
    from repro.core.scu.engine import FleetConfig

    cl = make_cluster(8)

    def producer(cluster, cid):
        for v in range(items):
            yield Compute(3)
            # blocking push: hardware backpressure, comparator fires on
            # every accepted event
            yield Scu("elw", ("fifo", 1, "push_wait"), v % 256)

    def consumer(cluster, cid):
        for _ in range(items):
            yield Scu("elw", ("fifo", 1, "pop"))

    def idle(cluster, cid):
        yield Compute(1)

    return FleetConfig(cluster=cl, programs=[producer, consumer] + [idle] * 6)


def test_fleet_comparator_during_other_configs_quiescent_span():
    """Adversarial segment-independence case: config B's FIFO comparator
    fires every few cycles while config A sits in a long quiescent span.
    Per-config results must stay bit-exact in both orders, and A's span
    must still be covered by fast-forward jumps (B's armed extension must
    not force A through full steps)."""
    from repro.core.scu.engine import simulate_fleet

    ref = []
    for mk in (_sleeper_config, _churner_config):
        cfg = mk()
        cfg.cluster.load(cfg.programs)
        ref.append(cfg.cluster.run())

    cfgs = [_sleeper_config(), _churner_config()]
    out = simulate_fleet(cfgs)
    assert out[0] == ref[0] and out[1] == ref[1]
    assert cfgs[0].cluster.ff_cycles > 0.9 * out[0].cycles, (
        "sleeper config degraded to stepping while the churner's "
        "comparator was armed"
    )

    # reversed member order: segment offsets must not matter
    cfgs = [_churner_config(), _sleeper_config()]
    out = simulate_fleet(cfgs)
    assert out[0] == ref[1] and out[1] == ref[0]


def test_fleet_members_finish_independently():
    """Early-finishing members are masked out: a 2-iteration config and a
    long config in one fleet both match their sequential runs, and the
    fleet leaves each member's local clock at its own final cycle."""
    from repro.core.scu.programs import make_fleet, prep_barrier_bench

    def build():
        return [
            prep_barrier_bench("scu", 8, sfr=0, iters=2),
            prep_barrier_bench("sw", 8, sfr=400, iters=40),
            prep_barrier_bench("fifo", 16, sfr=10, iters=6),
        ]

    seq = [b.run_sequential() for b in build()]
    benches = build()
    fleet = make_fleet(benches)
    for a, b in zip(seq, fleet):
        assert a.stats == b.stats
    cycles = [b.config.cluster.cycle for b in benches]
    assert cycles == [s.stats.cycles for s in seq]
    assert cycles[0] < cycles[1]  # wildly different finish times, one batch


def test_fleet_deadlock_raises_at_same_cycle():
    """A deadlocked member must hit its max_cycles cap exactly as the
    sequential engine does (jump to the cap, then raise)."""
    from repro.core.scu.engine import FleetConfig, simulate_fleet

    cl = make_cluster(2)

    def sleeper(cluster, cid):
        yield Scu("elw", ("notifier", 5, "wait"))

    def finisher(cluster, cid):
        yield Compute(3)

    dead = FleetConfig(
        cluster=cl, programs=[sleeper, finisher], max_cycles=4096
    )
    ok = FleetConfig(
        cluster=make_cluster(2),
        programs=[finisher, finisher],
        max_cycles=4096,
    )
    with pytest.raises(RuntimeError, match="did not finish"):
        simulate_fleet([ok, dead])
    assert dead.cluster.cycle == 4096
    assert dead.cluster.cores[0].state is CoreState.SLEEP


def test_simulate_fleet_validates_inputs():
    from repro.core.scu.engine import FleetConfig, simulate_fleet

    def prog(cluster, cid):
        yield Compute(1)

    assert simulate_fleet([]) == []
    with pytest.raises(ValueError, match="fastforward"):
        simulate_fleet([FleetConfig(
            cluster=make_cluster(2, mode="lockstep"), programs=[prog] * 2
        )])
    with pytest.raises(ValueError, match="programs"):
        simulate_fleet([FleetConfig(cluster=make_cluster(2), programs=[prog])])
    used = make_cluster(2)
    used.load([prog] * 2)
    used.run()
    with pytest.raises(ValueError, match="fresh"):
        simulate_fleet([FleetConfig(cluster=used, programs=[prog] * 2)])


def test_invalid_engine_mode_rejected():
    with pytest.raises(ValueError, match="mode"):
        Cluster(n_cores=2, mode="warp")


@pytest.mark.parametrize("mode", MODES)
def test_deadlock_raises_at_same_cycle(mode):
    """A core sleeping on an event that never comes must hit max_cycles in
    both modes -- the fast path may jump there, but not past it."""
    cl = make_cluster(2, mode=mode)

    def sleeper(cluster, cid):
        yield Scu("elw", ("notifier", 5, "wait"))

    def finisher(cluster, cid):
        yield Compute(3)

    cl.load([sleeper, finisher])
    with pytest.raises(RuntimeError, match="did not finish"):
        cl.run(max_cycles=4096)
    assert cl.cycle == 4096
    assert cl.cores[0].state is CoreState.SLEEP
