"""Infrastructure tests: trip-count-aware HLO analyzer + continuous batcher."""

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

import jax
import jax.numpy as jnp

from repro.launch.hlo_analysis import analyze_hlo
from repro.serve.batching import ContinuousBatcher, Request


# ---------------------------------------------------------------------------
# HLO analyzer: the roofline's data source must weight scan bodies correctly
# ---------------------------------------------------------------------------


def _dot_flops_of(fn, *args) -> float:
    txt = jax.jit(fn).lower(*args).compile().as_text()
    return analyze_hlo(txt).dot_flops


def test_hlo_analyzer_counts_scan_trip_count():
    """A matmul inside an 8-iteration scan must count ~8x one matmul."""
    d = 128
    x = jnp.ones((d, d), jnp.float32)
    w = jnp.ones((8, d, d), jnp.float32)

    def once(x, w0):
        return x @ w0

    def scanned(x, w):
        def body(c, wi):
            return c @ wi, None

        out, _ = jax.lax.scan(body, x, w)
        return out

    f_once = _dot_flops_of(once, x, w[0])
    f_scan = _dot_flops_of(scanned, x, w)
    assert f_once > 0
    ratio = f_scan / f_once
    assert 6.0 <= ratio <= 10.0, f"scan body weighting off: ratio {ratio:.2f}"


def test_hlo_analyzer_dot_flops_formula():
    """2*M*N*K for a plain matmul (within fusion-variation tolerance)."""
    m, k, n = 64, 256, 128
    a = jnp.ones((m, k), jnp.float32)
    b = jnp.ones((k, n), jnp.float32)
    flops = _dot_flops_of(lambda a, b: a @ b, a, b)
    expect = 2 * m * k * n
    assert abs(flops - expect) / expect < 0.01


def test_hlo_analyzer_sees_collectives():
    if jax.device_count() < 2:
        pytest.skip("needs >= 2 devices")
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.compat import make_axis_mesh

    mesh = make_axis_mesh((2,), ("x",))
    sh = NamedSharding(mesh, P(None, "x"))
    rep = NamedSharding(mesh, P(None, None))
    x = jax.device_put(jnp.ones((64, 64), jnp.float32), sh)
    w = jax.device_put(jnp.ones((64, 64), jnp.float32), sh)

    with mesh:
        # contraction over the sharded axis forces a cross-device reduction
        txt = (
            jax.jit(lambda x, w: x @ w.T, out_shardings=rep)
            .lower(x, w)
            .compile()
            .as_text()
        )
    s = analyze_hlo(txt)
    assert s.total_collective_count >= 1
    assert s.total_wire_bytes > 0


# ---------------------------------------------------------------------------
# Continuous batcher
# ---------------------------------------------------------------------------


def test_batcher_admits_and_finishes():
    b = ContinuousBatcher(batch_slots=2, max_seq=32)
    for rid in range(5):
        b.submit(Request(rid=rid, prompt=[1, 2, 3], max_new_tokens=4))
    steps = 0
    while not b.drain_done():
        b.admit()
        toks, pos = b.step_inputs()
        assert toks.shape == (2, 1) and pos.shape == (2,)
        b.observe(np.full((2,), 7, np.int64))
        steps += 1
        assert steps < 100
    assert len(b.finished) == 5
    for req in b.finished.values():
        assert req.generated == [7, 7, 7, 7]


def test_batcher_deadline_forces_finish():
    b = ContinuousBatcher(batch_slots=1, max_seq=64)
    b.submit(Request(rid=0, prompt=[1], max_new_tokens=1000, deadline_steps=3))
    b.admit()
    for _ in range(3):
        b.observe(np.zeros((1,), np.int64))
    assert 0 in b.finished  # straggler force-finished at the deadline


@settings(max_examples=30, deadline=None)
@given(
    slots=st.integers(min_value=1, max_value=4),
    n_reqs=st.integers(min_value=0, max_value=12),
    lens=st.integers(min_value=1, max_value=6),
)
def test_batcher_conservation(slots, n_reqs, lens):
    """Property: no request is lost or duplicated; slots never exceed capacity."""
    b = ContinuousBatcher(batch_slots=slots, max_seq=64)
    for rid in range(n_reqs):
        b.submit(Request(rid=rid, prompt=[1], max_new_tokens=lens))
    for _ in range(200):
        if b.drain_done():
            break
        b.admit()
        assert b.active <= slots
        b.observe(np.zeros((slots,), np.int64))
    assert sorted(b.finished) == list(range(n_reqs))
