"""Seedable arrival traces: determinism, distribution shape, validation."""

import pytest

from repro.serve.arrivals import bursty_trace, poisson_trace


# ---------------------------------------------------------------------------
# poisson_trace
# ---------------------------------------------------------------------------


def test_poisson_trace_is_seed_deterministic():
    a = poisson_trace(0.05, 200, seed=42)
    assert a == poisson_trace(0.05, 200, seed=42)
    assert a != poisson_trace(0.05, 200, seed=43)


def test_poisson_trace_shape_and_monotonicity():
    t = poisson_trace(0.02, 150, seed=7)
    assert len(t) == 150
    assert all(isinstance(v, int) for v in t)
    assert all(b >= a >= 0 for a, b in zip(t, t[1:]))


def test_poisson_trace_mean_gap_tracks_rate():
    """Empirical mean gap ~ 1/rate (floored exponential gaps, so the mean
    sits just under 1/rate; a generous +-30% band keeps this seed-robust)."""
    rate, n = 0.02, 2000
    t = poisson_trace(rate, n, seed=3)
    mean_gap = t[-1] / (n - 1)
    assert 0.7 / rate < mean_gap < 1.3 / rate


def test_poisson_trace_high_rate_degenerates_into_batches():
    """rate >> 1 floors most gaps to zero: many same-round arrivals."""
    t = poisson_trace(10.0, 100, seed=5)
    assert len(set(t)) < len(t)


def test_poisson_trace_edge_cases():
    assert poisson_trace(0.1, 0, seed=0) == []
    with pytest.raises(ValueError):
        poisson_trace(0.0, 10, seed=0)
    with pytest.raises(ValueError):
        poisson_trace(-1.0, 10, seed=0)
    with pytest.raises(ValueError):
        poisson_trace(0.1, -1, seed=0)


# ---------------------------------------------------------------------------
# bursty_trace
# ---------------------------------------------------------------------------


def test_bursty_trace_is_seed_deterministic():
    a = bursty_trace(4, 8, 50, seed=11, jitter=5)
    assert a == bursty_trace(4, 8, 50, seed=11, jitter=5)
    assert a != bursty_trace(4, 8, 50, seed=12, jitter=5)


def test_bursty_trace_shape_without_jitter():
    """jitter=0 is fully deterministic regardless of seed: bursts of
    identical timestamps exactly gap_rounds apart."""
    t = bursty_trace(3, 4, 100, seed=0)
    assert t == [0] * 4 + [100] * 4 + [200] * 4
    assert t == bursty_trace(3, 4, 100, seed=999)


def test_bursty_trace_jitter_stays_in_band_and_sorted():
    n_bursts, burst, gap, jitter = 5, 6, 40, 7
    t = bursty_trace(n_bursts, burst, gap, seed=21, jitter=jitter)
    assert len(t) == n_bursts * burst
    assert t == sorted(t)
    # every arrival stays within its burst's jitter window
    assert all(
        any(b * gap <= v <= b * gap + jitter for b in range(n_bursts))
        for v in t
    )


def test_bursty_trace_edge_cases():
    assert bursty_trace(0, 5, 10, seed=0) == []
    assert bursty_trace(5, 0, 10, seed=0) == []
    with pytest.raises(ValueError):
        bursty_trace(-1, 5, 10, seed=0)
    with pytest.raises(ValueError):
        bursty_trace(1, -5, 10, seed=0)
    with pytest.raises(ValueError):
        bursty_trace(1, 5, -10, seed=0)
    with pytest.raises(ValueError):
        bursty_trace(1, 5, 10, seed=0, jitter=-1)
