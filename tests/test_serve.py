"""Serving-path correctness: teacher-forced decode == full forward.

Feeds a prompt token-by-token through ``serve_step`` (building the KV/MLA/
SSM caches incrementally) and checks the final-position logits against a
single full-sequence ``lm_forward`` -- the strongest end-to-end check that
the cache layouts, decode attention (incl. absorbed MLA) and the SSD
recurrent step agree with the training path.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_smoke_config
from repro.launch.mesh import make_host_mesh
from repro.models.lm import init_lm, lm_forward, lm_logits
from repro.serve.decode import init_cache, make_serve_step

KEY = jax.random.PRNGKey(0)

# one arch per decode code path: GQA, MLA+MoE, pure SSD, hybrid group scan
ARCHS = ["stablelm-3b", "deepseek-v2-lite-16b", "mamba2-1.3b", "jamba-v0.1-52b"]


@pytest.mark.parametrize("arch", ARCHS)
def test_teacher_forced_decode_matches_forward(arch):
    if jax.device_count() < 4:
        pytest.skip("needs 4 host devices")
    cfg = get_smoke_config(arch)
    # f32 compute: the check targets *structural* equivalence of the cache
    # paths; bf16 noise accumulated across hybrid stacks is tested elsewhere
    cfg = dataclasses.replace(cfg, dtype="float32")
    if cfg.moe is not None:
        # dropless for this test: capacity-drop decisions legitimately
        # differ between the batched prefill (T=b*s tokens compete) and
        # per-token decode (T=b) -- ample capacity removes the difference
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=64.0)
        )
    mesh = make_host_mesh(data=2, model=2)
    b, prompt_len, max_seq = 2, 8, 16

    with mesh:
        params = init_lm(KEY, cfg, jnp.float32)
        tokens = jax.random.randint(
            jax.random.PRNGKey(1), (b, prompt_len), 0, cfg.vocab_size
        )

        # reference: full forward, logits at the last prompt position
        hidden = lm_forward(params, cfg, tokens=tokens, remat_policy="none")
        ref_logits = lm_logits(params, cfg, hidden[:, -1, :]).astype(jnp.float32)

        # decode: feed the prompt token-by-token through the cache path
        serve_fn, _, _, _ = make_serve_step(cfg, mesh, b, max_seq)
        serve_fn = jax.jit(serve_fn)
        cache = init_cache(cfg, b, max_seq)
        logits = None
        for t in range(prompt_len):
            pos = jnp.full((b,), t, jnp.int32)
            _next, logits, cache = serve_fn(params, cache, tokens[:, t : t + 1], pos)

    np.testing.assert_allclose(
        np.asarray(logits), np.asarray(ref_logits), rtol=2e-4, atol=2e-4
    )
    # greedy choices must be epsilon-optimal under the reference logits
    # (exact argmax equality is ill-posed at random init: near-uniform
    # logits tie within bf16 noise)
    ref = np.asarray(ref_logits)
    chosen = ref[np.arange(ref.shape[0]), np.asarray(jnp.argmax(logits, -1))]
    assert (ref.max(-1) - chosen < 1e-3).all()
