"""The ``benchmarks/run.py --json`` artifact contract + the regression gate.

Two consumers depend on the artifact's shape staying put: the perf-smoke CI
artifact (cross-PR trajectory) and ``scripts/bench_compare.py`` (the gating
benchmark-regression check).  These tests pin the schema via the committed
golden baseline and prove the gate actually fails on an injected cycle-count
regression -- the property the CI job relies on.
"""

import copy
import importlib.util
import json
import sys
from pathlib import Path

import pytest

REPO = Path(__file__).resolve().parent.parent
GOLDEN = REPO / "benchmarks" / "golden" / "BENCH_baseline.json"

# scripts/ is not a package; load the gate module by path
_spec = importlib.util.spec_from_file_location(
    "bench_compare", REPO / "scripts" / "bench_compare.py"
)
bench_compare = importlib.util.module_from_spec(_spec)
sys.modules.setdefault("bench_compare", bench_compare)
_spec.loader.exec_module(bench_compare)


@pytest.fixture(scope="module")
def baseline():
    with open(GOLDEN) as f:
        return json.load(f)


# ---------------------------------------------------------------------------
# Schema: the committed baseline must satisfy the contract, and the
# validator must actually catch drift
# ---------------------------------------------------------------------------


def test_golden_baseline_satisfies_schema(baseline):
    assert bench_compare.validate_schema(baseline) == []


def test_schema_requires_every_section(baseline):
    for key in (
        "table1", "table1_scaling", "fig5", "fig5_scaling", "table2",
        "chain", "chain_scaling", "work_queue", "work_queue_scaling",
        "engine_perf", "traffic", "resilience", "fault_domains",
        "preemption", "jax_barriers_ok",
    ):
        broken = {k: v for k, v in baseline.items() if k != key}
        errors = bench_compare.validate_schema(broken)
        assert any(key in e for e in errors), f"dropping {key!r} not caught"


def test_schema_catches_type_drift(baseline):
    broken = copy.deepcopy(baseline)
    broken["table1"][0]["cycles"] = "fast"  # a string is not a cycle count
    assert any("cycles" in e for e in bench_compare.validate_schema(broken))

    broken = copy.deepcopy(baseline)
    del broken["table1"][0]["policy"]
    assert any("policy" in e for e in bench_compare.validate_schema(broken))

    broken = copy.deepcopy(baseline)
    broken["engine_perf"]["cycles_per_sec"].pop("fastforward")
    assert any(
        "fastforward" in e for e in bench_compare.validate_schema(broken)
    )

    broken = copy.deepcopy(baseline)
    del broken["engine_perf"]["fleet"]["speedup_8core"]
    assert any(
        "speedup_8core" in e for e in bench_compare.validate_schema(broken)
    )

    broken = copy.deepcopy(baseline)
    del broken["engine_perf"]["fleet"]
    assert any("fleet" in e for e in bench_compare.validate_schema(broken))


def test_schema_catches_traffic_drift(baseline):
    broken = copy.deepcopy(baseline)
    del broken["traffic"]["scenarios"]["bursty"]["continuous"]["p99_latency_rounds"]
    assert any(
        "p99_latency_rounds" in e for e in bench_compare.validate_schema(broken)
    )

    broken = copy.deepcopy(baseline)
    policy = next(iter(broken["traffic"]["energy_tail"]))
    del broken["traffic"]["energy_tail"][policy]["p99_spin_pj"]
    assert any(
        "p99_spin_pj" in e for e in bench_compare.validate_schema(broken)
    )

    broken = copy.deepcopy(baseline)
    del broken["traffic"]["speedup"]
    assert any("speedup" in e for e in bench_compare.validate_schema(broken))


def test_traffic_baseline_shows_continuous_batching_win(baseline):
    """The committed baseline must carry the measured win: under bursty
    arrivals, continuous admission beats the drain baseline on p99 latency
    and idle-lane fraction (both deterministic round-counted metrics)."""
    bursty = baseline["traffic"]["scenarios"]["bursty"]
    cont, drain = bursty["continuous"], bursty["drain"]
    assert cont["p99_latency_rounds"] < drain["p99_latency_rounds"]
    assert cont["idle_lane_fraction"] < drain["idle_lane_fraction"]
    assert cont["rounds"] <= drain["rounds"]


def test_traffic_latency_metrics_are_hard_gated(baseline):
    """Round-counted traffic metrics gate like cycle counts: a doctored
    p99 regression must trip the hard comparison."""
    doctored = copy.deepcopy(baseline)
    cell = doctored["traffic"]["scenarios"]["bursty"]["continuous"]
    cell["p99_latency_rounds"] = cell["p99_latency_rounds"] * 1.10
    regressions, _ = bench_compare.compare(baseline, doctored)
    assert any("p99_latency_rounds" in r for r in regressions)


def test_schema_catches_resilience_drift(baseline):
    broken = copy.deepcopy(baseline)
    rate = next(iter(broken["resilience"]["cells"]))
    del broken["resilience"]["cells"][rate]["retry"]["failure_rate"]
    assert any(
        "failure_rate" in e for e in bench_compare.validate_schema(broken)
    )

    broken = copy.deepcopy(baseline)
    broken["resilience"]["cells"] = {}
    assert any("cells" in e for e in bench_compare.validate_schema(broken))


def test_resilience_metrics_are_hard_gated(baseline):
    """Cycle- and round-counted recovery metrics gate like cycle counts: a
    doctored wasted-cycles or failure-rate increase trips the comparison."""
    doctored = copy.deepcopy(baseline)
    cell = doctored["resilience"]["cells"]["rate0.5"]["none"]
    cell["wasted_cycles"] = cell["wasted_cycles"] * 2
    regressions, _ = bench_compare.compare(baseline, doctored)
    assert any("wasted_cycles" in r for r in regressions)

    doctored = copy.deepcopy(baseline)
    cell = doctored["resilience"]["cells"]["rate0.5"]["retry"]
    cell["failure_rate"] = 0.5  # recovery stopped recovering
    regressions, _ = bench_compare.compare(baseline, doctored)
    assert any("retry/failure_rate" in r for r in regressions)


def test_resilience_baseline_shows_recovery_win(baseline):
    """The committed baseline must carry the measured claim: at the faulty
    rate, fail-fast loses jobs while every recovery mode completes the
    stream -- and the watchdog does it without wasting a cycle."""
    faulty = baseline["resilience"]["cells"]["rate0.5"]
    assert faulty["none"]["failure_rate"] > 0
    for mode in ("retry", "degrade", "watchdog"):
        assert faulty[mode]["failure_rate"] == 0.0
    assert faulty["watchdog"]["wasted_cycles"] == 0
    assert faulty["watchdog"]["watchdog_releases"] > 0
    assert faulty["degrade"]["degraded_jobs"] > 0
    clean = baseline["resilience"]["cells"]["rate0"]
    assert all(c["failure_rate"] == 0.0 for c in clean.values())


def test_schema_catches_fault_domain_drift(baseline):
    broken = copy.deepcopy(baseline)
    rate = next(iter(broken["fault_domains"]["cells"]))
    del broken["fault_domains"]["cells"][rate]["reroute"]["wasted_cycles"]
    assert any(
        "wasted_cycles" in e for e in bench_compare.validate_schema(broken)
    )

    broken = copy.deepcopy(baseline)
    broken["fault_domains"]["cells"] = {}
    assert any("cells" in e for e in bench_compare.validate_schema(broken))


def test_fault_domain_metrics_are_hard_gated(baseline):
    """Routing metrics gate like cycle counts: a doctored wasted-cycles
    increase or a lost job under reroute trips the hard comparison (the
    zero failure-rate baseline gates any increase absolutely)."""
    doctored = copy.deepcopy(baseline)
    cell = doctored["fault_domains"]["cells"]["rate1"]["quarantine"]
    cell["wasted_cycles"] = cell["wasted_cycles"] * 2
    regressions, _ = bench_compare.compare(baseline, doctored)
    assert any("quarantine/wasted_cycles" in r for r in regressions)

    doctored = copy.deepcopy(baseline)
    cell = doctored["fault_domains"]["cells"]["rate1"]["reroute"]
    cell["failure_rate"] = 0.25  # rerouting stopped rescuing jobs
    regressions, _ = bench_compare.compare(baseline, doctored)
    assert any("reroute/failure_rate" in r for r in regressions)


def test_fault_domain_baseline_shows_routing_win(baseline):
    """The committed baseline must carry the measured claim: with a sick
    domain, in-place retry loses jobs while reroute and reroute+quarantine
    complete the stream, and quarantine strictly cuts wasted cycles."""
    faulty = baseline["fault_domains"]["cells"]["rate1"]
    assert faulty["inplace"]["failed_jobs"] > 0
    for policy in ("reroute", "quarantine"):
        assert faulty[policy]["failure_rate"] == 0.0
    assert faulty["reroute"]["reroutes"] > 0
    assert faulty["quarantine"]["quarantines"] > 0
    assert (faulty["quarantine"]["wasted_cycles"]
            < faulty["reroute"]["wasted_cycles"])
    clean = baseline["fault_domains"]["cells"]["rate0"]
    for c in clean.values():
        assert c["failure_rate"] == 0.0
        assert c["reroutes"] == 0 and c["quarantines"] == 0


def test_schema_catches_preemption_drift(baseline):
    broken = copy.deepcopy(baseline)
    del broken["preemption"]["migration"]["migrate"]["wasted_cycles"]
    assert any(
        "wasted_cycles" in e for e in bench_compare.validate_schema(broken)
    )

    broken = copy.deepcopy(baseline)
    del broken["preemption"]["schedule"]["preempt"]["hi_latency_rounds"]
    assert any(
        "hi_latency_rounds" in e for e in bench_compare.validate_schema(broken)
    )

    broken = copy.deepcopy(baseline)
    broken["preemption"]["schedule"] = {}
    assert any("schedule" in e for e in bench_compare.validate_schema(broken))


def test_preemption_metrics_are_hard_gated(baseline):
    """Migration wasted cycles and high-priority latency gate like cycle
    counts; the zero wasted-cycles baseline of the preempting service
    gates any increase absolutely."""
    doctored = copy.deepcopy(baseline)
    cell = doctored["preemption"]["migration"]["migrate"]
    cell["wasted_cycles"] = cell["wasted_cycles"] * 2
    regressions, _ = bench_compare.compare(baseline, doctored)
    assert any("migrate/wasted_cycles" in r for r in regressions)

    doctored = copy.deepcopy(baseline)
    cell = doctored["preemption"]["schedule"]["preempt"]
    cell["hi_latency_rounds"] = cell["hi_latency_rounds"] * 2
    cell["wasted_cycles"] = 500  # preemption started burning victim cycles
    regressions, _ = bench_compare.compare(baseline, doctored)
    assert any("preempt/hi_latency_rounds" in r for r in regressions)
    assert any("preempt/wasted_cycles" in r for r in regressions)


def test_preemption_baseline_shows_checkpoint_win(baseline):
    """The committed baseline must carry the measured claims: resuming
    from a checkpoint wastes strictly fewer cycles than restart-reroute
    on the same fault script, and the preempting service admits the
    high-priority job with zero queue rounds and zero wasted victim
    cycles while cutting its latency vs both fifo and non-preempting
    priority order."""
    mig = baseline["preemption"]["migration"]
    assert mig["migrate"]["failure_rate"] == 0.0
    assert mig["restart"]["failure_rate"] == 0.0
    assert mig["migrate"]["migrations"] >= 1
    assert mig["migrate"]["wasted_cycles"] < mig["restart"]["wasted_cycles"]
    sched = baseline["preemption"]["schedule"]
    assert sched["preempt"]["preemptions"] >= 1
    assert sched["preempt"]["hi_queue_rounds"] == 0
    assert sched["preempt"]["wasted_cycles"] == 0
    assert (sched["preempt"]["hi_latency_rounds"]
            < sched["priority"]["hi_latency_rounds"]
            <= sched["fifo"]["hi_latency_rounds"])


def test_schema_catches_chain_row_drift(baseline):
    broken = copy.deepcopy(baseline)
    del broken["chain"]["rows"][0]["cycles_per_item"]
    assert any(
        "cycles_per_item" in e for e in bench_compare.validate_schema(broken)
    )


def test_artifact_carries_every_registered_policy(baseline):
    """Table-1/Fig-5/chain/work-queue rows exist for every registered
    policy, including the tree4/tree_ew/fifo extensions -- the
    per-discipline benchmark surface."""
    from repro.sync import available_policies

    table1_policies = {r["policy"] for r in baseline["table1"]}
    fig5_policies = set(baseline["fig5"])
    chain_policies = {r["policy"] for r in baseline["chain"]["rows"]}
    wq_policies = {r["policy"] for r in baseline["work_queue"]["rows"]}
    for policy in available_policies():
        assert policy in table1_policies, f"{policy}: no Table-1 row"
        assert policy in fig5_policies, f"{policy}: no Fig-5 row"
        assert policy in chain_policies, f"{policy}: no chain row"
        assert policy in wq_policies, f"{policy}: no work-queue row"


def test_scaling_rows_reach_256_cores(baseline):
    """Every scaling benchmark carries 128- and 256-core rows (the
    vectorized-engine acceptance surface)."""
    t1_counts = {n for r in baseline["table1_scaling"] for n in r["core_counts"]}
    fig5_counts = {int(n) for n in baseline["fig5_scaling"]}
    chain_counts = {r["n_cores"] for r in baseline["chain_scaling"]}
    wq_counts = {r["n_cores"] for r in baseline["work_queue_scaling"]}
    for counts, name in (
        (t1_counts, "table1_scaling"),
        (fig5_counts, "fig5_scaling"),
        (chain_counts, "chain_scaling"),
        (wq_counts, "work_queue_scaling"),
    ):
        assert {128, 256} <= counts, f"{name}: missing 128/256-core rows"


# ---------------------------------------------------------------------------
# The regression gate
# ---------------------------------------------------------------------------


def test_gate_passes_on_identical_artifact(baseline):
    regressions, _ = bench_compare.compare(baseline, baseline)
    assert regressions == []
    assert len(bench_compare.extract_metrics(baseline)) > 100


def test_gate_fails_on_injected_cycle_regression(baseline):
    """The property CI relies on: a cycle-count regression > threshold on a
    gated key number must fail the comparison."""
    doctored = copy.deepcopy(baseline)
    row = doctored["table1"][0]
    row["cycles"] = [c * 1.05 for c in row["cycles"]]  # +5% > 2% threshold
    regressions, _ = bench_compare.compare(baseline, doctored)
    assert regressions, "a +5% cycle regression must trip the gate"
    assert any(row["primitive"] in r and row["policy"] in r for r in regressions)


def test_gate_tolerates_sub_threshold_jitter(baseline):
    doctored = copy.deepcopy(baseline)
    row = doctored["table1"][0]
    row["cycles"] = [c * 1.01 for c in row["cycles"]]  # below the 2% gate
    regressions, _ = bench_compare.compare(baseline, doctored)
    assert regressions == []


def test_gate_fails_on_disappearing_metric(baseline):
    doctored = copy.deepcopy(baseline)
    doctored["table1"] = doctored["table1"][1:]  # a gated row vanished
    regressions, _ = bench_compare.compare(baseline, doctored)
    assert any("disappeared" in r for r in regressions)


def test_gate_fails_on_min_sfr_regression(baseline):
    doctored = copy.deepcopy(baseline)
    policy = next(iter(doctored["fig5"]))
    entry = doctored["fig5"][policy]
    entry["min_sfr_energy_10pct"] = entry["min_sfr_energy_10pct"] * 1.10
    regressions, _ = bench_compare.compare(baseline, doctored)
    assert any("min_sfr_energy_10pct" in r for r in regressions)


def test_throughput_soft_gate(baseline):
    """Engine-throughput gate: a collapse below 0.5x of the committed
    baseline cyc/s fails, a dip below 1.0x only warns, parity is silent.
    Covers the fastforward, contended and fleet-dispatch speedup keys."""
    fails, warns = bench_compare.compare_throughput(baseline, baseline)
    assert fails == [] and warns == []

    def scaled(f):
        doctored = copy.deepcopy(baseline)
        perf = doctored["engine_perf"]
        perf["speedup"] *= f
        perf["contended"]["speedup"] *= f
        perf["fleet"]["speedup"] *= f
        perf["fleet"]["speedup_8core"] *= f
        doctored["traffic"]["speedup"] *= f
        return doctored

    fails, warns = bench_compare.compare_throughput(baseline, scaled(0.4))
    assert fails, "a 0.4x throughput collapse must fail the soft gate"
    assert any("fleet" in f for f in fails), "fleet speedup must be gated"
    assert any("traffic" in f for f in fails), "traffic speedup must be gated"
    fails, warns = bench_compare.compare_throughput(baseline, scaled(0.8))
    assert not fails and warns, "a 0.8x dip must warn, not fail"
    fails, warns = bench_compare.compare_throughput(baseline, scaled(1.3))
    assert not fails and not warns


def test_run_only_rejects_unknown_section():
    """benchmarks/run.py --only validates section names before any heavy
    import and exits nonzero on unknown ones (the CI/iteration contract)."""
    import os
    import subprocess

    env = dict(os.environ)
    env["PYTHONPATH"] = str(REPO / "src")
    r = subprocess.run(
        [sys.executable, "-m", "benchmarks.run", "--only", "warp,table1"],
        capture_output=True, text=True, cwd=REPO, env=env, timeout=120,
    )
    assert r.returncode == 2
    assert "unknown section" in r.stderr
    assert "warp" in r.stderr


def test_throughput_gate_wired_into_main(tmp_path, baseline):
    """The CLI must fail (exit 1) on a hard throughput collapse."""
    base_p = tmp_path / "base.json"
    base_p.write_text(json.dumps(baseline))
    doctored = copy.deepcopy(baseline)
    doctored["engine_perf"]["contended"]["speedup"] *= 0.3
    cur_p = tmp_path / "slow.json"
    cur_p.write_text(json.dumps(doctored))
    assert bench_compare.main([str(base_p), str(cur_p)]) == 1


def test_main_exit_codes(tmp_path, baseline):
    """End-to-end: the CLI exits 0 on parity, 1 on regression, 2 on schema
    violations -- the contract scripts/ci.sh gates on."""
    base_p = tmp_path / "base.json"
    base_p.write_text(json.dumps(baseline))

    assert bench_compare.main([str(base_p), str(base_p)]) == 0

    doctored = copy.deepcopy(baseline)
    doctored["table2"][0]["cycles"] = {
        k: v * 2 for k, v in doctored["table2"][0]["cycles"].items()
    }
    cur_p = tmp_path / "regressed.json"
    cur_p.write_text(json.dumps(doctored))
    assert bench_compare.main([str(base_p), str(cur_p)]) == 1

    invalid = {k: v for k, v in baseline.items() if k != "chain"}
    bad_p = tmp_path / "invalid.json"
    bad_p.write_text(json.dumps(invalid))
    assert bench_compare.main([str(base_p), str(bad_p)]) == 2

    assert bench_compare.main([str(base_p), str(tmp_path / "missing.json")]) == 2
