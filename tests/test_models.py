"""Model-layer correctness tests: oracles, equivalences, param counts."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.configs.base import MoEConfig
from repro.configs.registry import get_config, get_smoke_config, list_archs
from repro.models.layers.attention import chunked_attention, naive_attention
from repro.models.layers.moe import dispatch_indices, router_topk
from repro.models.layers.ssm import ssd_chunked, ssd_recurrent
from repro.models.lm import init_lm, lm_loss

KEY = jax.random.PRNGKey(0)


# ---------------------------------------------------------------------------
# Attention: chunked online-softmax == naive
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("h,kvh", [(4, 4), (8, 2)])
@pytest.mark.parametrize("sq", [128, 256])
def test_chunked_attention_matches_naive(h, kvh, sq):
    d = 32
    b = 2
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (b, sq, h, d), jnp.float32)
    k = jax.random.normal(ks[1], (b, sq, kvh, d), jnp.float32)
    v = jax.random.normal(ks[2], (b, sq, kvh, d), jnp.float32)
    ref = naive_attention(q, k, v, causal=True)
    out = chunked_attention(q, k, v, causal=True, q_chunk=64, kv_chunk=64)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-5, atol=2e-5)


def test_chunked_attention_bf16_close():
    b, s, h, d = 1, 256, 4, 64
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (b, s, h, d), jnp.bfloat16)
    k = jax.random.normal(ks[1], (b, s, h, d), jnp.bfloat16)
    v = jax.random.normal(ks[2], (b, s, h, d), jnp.bfloat16)
    ref = naive_attention(q.astype(jnp.float32), k.astype(jnp.float32), v.astype(jnp.float32))
    out = chunked_attention(q, k, v, q_chunk=128, kv_chunk=128)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref), rtol=0.1, atol=0.05
    )


# ---------------------------------------------------------------------------
# SSD: chunked dual form == token-by-token recurrence
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("chunk", [16, 32, 64])
def test_ssd_chunked_matches_recurrent(chunk):
    b, s, h, p, g, n = 2, 64, 4, 8, 2, 16
    ks = jax.random.split(KEY, 5)
    x = jax.random.normal(ks[0], (b, s, h, p), jnp.float32) * 0.5
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h), jnp.float32))
    A = -jnp.exp(jax.random.normal(ks[2], (h,), jnp.float32) * 0.3)
    B = jax.random.normal(ks[3], (b, s, g, n), jnp.float32) * 0.3
    C = jax.random.normal(ks[4], (b, s, g, n), jnp.float32) * 0.3
    y_ref, st_ref = ssd_recurrent(x, dt, A, B, C)
    y, st = ssd_chunked(x, dt, A, B, C, chunk=chunk)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(st), np.asarray(st_ref), rtol=2e-4, atol=2e-4)


def test_ssd_chunked_initial_state_continuation():
    """Processing [part1; part2] == processing part2 with part1's final state."""
    b, s, h, p, g, n = 1, 64, 2, 8, 1, 8
    ks = jax.random.split(KEY, 5)
    x = jax.random.normal(ks[0], (b, s, h, p)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
    A = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.3)
    B = jax.random.normal(ks[3], (b, s, g, n)) * 0.3
    C = jax.random.normal(ks[4], (b, s, g, n)) * 0.3
    y_full, st_full = ssd_chunked(x, dt, A, B, C, chunk=16)
    half = s // 2
    y1, st1 = ssd_chunked(x[:, :half], dt[:, :half], A, B[:, :half], C[:, :half], chunk=16)
    y2, st2 = ssd_chunked(
        x[:, half:], dt[:, half:], A, B[:, half:], C[:, half:], chunk=16,
        initial_state=st1,
    )
    np.testing.assert_allclose(np.asarray(y2), np.asarray(y_full[:, half:]), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(st2), np.asarray(st_full), rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# MoE dispatch
# ---------------------------------------------------------------------------


def _dense_moe_reference(xf, router, gate, up, down, m: MoEConfig):
    """Loop-over-experts reference (no capacity drops)."""
    logits = xf.astype(jnp.float32) @ router
    weights, idx = router_topk(logits, m)
    T, d = xf.shape
    out = jnp.zeros((T, d), jnp.float32)
    for e in range(m.n_experts):
        h = jax.nn.silu(xf @ gate[e]) * (xf @ up[e])
        y = h @ down[e]
        w = ((idx == e) * weights).sum(-1)  # (T,)
        out = out + w[:, None] * y.astype(jnp.float32)
    return out


def test_moe_matches_dense_reference_when_capacity_ample():
    m = MoEConfig(n_experts=8, top_k=2, d_ff_expert=32, capacity_factor=8.0)
    T, d = 64, 16
    ks = jax.random.split(KEY, 5)
    xf = jax.random.normal(ks[0], (T, d), jnp.float32)
    router = jax.random.normal(ks[1], (d, m.n_experts), jnp.float32)
    gate = jax.random.normal(ks[2], (m.n_experts, d, m.d_ff_expert)) * 0.1
    up = jax.random.normal(ks[3], (m.n_experts, d, m.d_ff_expert)) * 0.1
    down = jax.random.normal(ks[4], (m.n_experts, m.d_ff_expert, d)) * 0.1

    from repro.configs.base import ModelConfig
    from repro.models.layers.moe import moe_apply

    cfg = ModelConfig(
        name="t", family="moe", n_layers=1, d_model=d, n_heads=1, n_kv_heads=1,
        d_ff=32, vocab_size=8, moe=m,
    )
    params = {"router": router, "gate": gate, "up": up, "down": down}
    out = moe_apply(params, cfg, xf[None])  # (1, T, d)
    ref = _dense_moe_reference(xf, router, gate, up, down, m)
    np.testing.assert_allclose(
        np.asarray(out[0], np.float32), np.asarray(ref), rtol=2e-3, atol=2e-3
    )


@settings(max_examples=25, deadline=None)
@given(
    T=st.integers(min_value=4, max_value=64),
    E=st.sampled_from([4, 8, 16]),
    K=st.integers(min_value=1, max_value=3),
    seed=st.integers(min_value=0, max_value=2**16),
)
def test_dispatch_indices_properties(T, E, K, seed):
    """Property: every kept slot lands in the right expert block, ranks are
    unique per expert, and drops only happen beyond capacity."""
    idx = jax.random.randint(jax.random.PRNGKey(seed), (T, K), 0, E)
    C = max(1, (T * K) // E)
    dest, token, order = dispatch_indices(idx, E, C)
    dest = np.asarray(dest)
    token = np.asarray(token)
    flat_expert = np.asarray(idx).reshape(-1)[np.asarray(order)]
    kept = dest < E * C
    # kept slots land in their expert's block
    assert (dest[kept] // C == flat_expert[kept]).all()
    # slots within one expert have unique positions
    for e in range(E):
        slots = dest[kept & (flat_expert == e)]
        assert len(np.unique(slots)) == len(slots)
        assert len(slots) == min(C, (flat_expert == e).sum())
    # every slot's source token matches its expert assignment
    orig = np.asarray(idx)
    for s_i in np.where(kept)[0]:
        assert flat_expert[s_i] in orig[token[s_i]]


# ---------------------------------------------------------------------------
# Full-model parameter counts vs published sizes
# ---------------------------------------------------------------------------

PUBLISHED = {
    # name: (total params, tolerance fraction)
    "mamba2-1.3b": (1.3e9, 0.15),
    "jamba-v0.1-52b": (52e9, 0.15),
    "deepseek-v2-lite-16b": (16e9, 0.15),
    "qwen3-moe-30b-a3b": (30e9, 0.15),
    "command-r-plus-104b": (104e9, 0.15),
    "phi4-mini-3.8b": (3.8e9, 0.20),
    "stablelm-3b": (2.8e9, 0.25),
    # the assigned config (d_ff=13440, untied 92k vocab) computes to 8.2B;
    # the "7B" name undercounts embeddings -- assignment numbers govern
    "codeqwen1.5-7b": (7e9, 0.20),
    "llava-next-34b": (34e9, 0.15),
}


@pytest.mark.parametrize("name", sorted(PUBLISHED))
def test_param_count_matches_published(name):
    cfg = get_config(name)
    n = cfg.n_params()
    target, tol = PUBLISHED[name]
    assert abs(n - target) / target < tol, f"{name}: {n/1e9:.2f}B vs {target/1e9:.1f}B"


ACTIVE = {
    "qwen3-moe-30b-a3b": (3e9, 0.35),  # A3B
    "deepseek-v2-lite-16b": (2.4e9, 0.35),
}


@pytest.mark.parametrize("name", sorted(ACTIVE))
def test_active_params(name):
    cfg = get_config(name)
    n = cfg.n_active_params()
    target, tol = ACTIVE[name]
    assert abs(n - target) / target < tol, f"{name}: active {n/1e9:.2f}B vs {target/1e9:.1f}B"


# ---------------------------------------------------------------------------
# Smoke: every arch runs a forward/loss step with finite output
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name", list_archs())
def test_arch_smoke_forward(name):
    cfg = get_smoke_config(name)
    params = init_lm(KEY, cfg)
    b, s = 2, 32
    if cfg.frontend:
        batch = {
            "embeddings": jax.random.normal(KEY, (b, s, cfg.d_model), jnp.bfloat16),
            "labels": jnp.zeros((b, s), jnp.int32),
        }
    else:
        batch = {
            "tokens": jnp.zeros((b, s), jnp.int32),
            "labels": jnp.zeros((b, s), jnp.int32),
        }
    loss = jax.jit(lambda p, bt: lm_loss(p, cfg, bt))(params, batch)
    assert np.isfinite(float(loss))
    assert 2.0 < float(loss) < 8.0  # ~ln(vocab) at random init


def test_flash_attention_gradients_match_naive():
    """custom-VJP flash backward == autodiff through naive attention."""
    b, s, h, kvh, d = 1, 128, 4, 2, 16
    ks = jax.random.split(KEY, 4)
    q = jax.random.normal(ks[0], (b, s, h, d), jnp.float32)
    k = jax.random.normal(ks[1], (b, s, kvh, d), jnp.float32)
    v = jax.random.normal(ks[2], (b, s, kvh, d), jnp.float32)
    tangent = jax.random.normal(ks[3], (b, s, h, d), jnp.float32)

    def loss_flash(q, k, v):
        return jnp.sum(chunked_attention(q, k, v, q_chunk=32, kv_chunk=32) * tangent)

    def loss_naive(q, k, v):
        return jnp.sum(naive_attention(q, k, v, causal=True) * tangent)

    gf = jax.grad(loss_flash, argnums=(0, 1, 2))(q, k, v)
    gn = jax.grad(loss_naive, argnums=(0, 1, 2))(q, k, v)
    for a, bb in zip(gf, gn):
        np.testing.assert_allclose(np.asarray(a), np.asarray(bb), rtol=2e-4, atol=2e-4)
